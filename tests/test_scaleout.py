"""Scale-out layer: 3-tier fat-tree structure + flow-sharded bit-identity.

Covers the fat-tree routing-matrix contract (tier slices partition the
link axis, hops land in their tiers, intra-pod flows ride the bypass),
packet conservation ACROSS tiers through the unchanged engine (ample
capacity: every delivered inter-pod packet crosses all four physical
tiers exactly once), pod-aligned placement, and the flow-sharded engine's
headline promise: bit-identical results to the unsharded sweeps.

Bit-identity is pinned two ways so it holds on any host:
  * vmap-emulated collectives (``jax.vmap(..., axis_name=FLOW_AXIS)``
    implements axis_index/psum/pmax/all_gather) — runs on ONE device,
    including the non-divisible flow-count padding path;
  * real ``shard_map`` over a 1-device mesh always, and over 2 devices
    when visible (CI's 2-device job sets
    ``XLA_FLAGS=--xla_force_host_platform_device_count=2``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.net.cluster import (
    cluster_fat_tree_topology,
    place_jobs_pods,
    sweep_cluster,
)
from repro.net.jobs import compile_job, sweep_job
from repro.net.scenarios import (
    FAT_TREE_SCENARIO_NAMES,
    fat_tree_scenarios,
    job_scenarios,
    pair_scenarios,
    stack_scenarios,
)
from repro.net.sender import (
    FLOW_AXIS,
    SenderSpec,
    flow_mesh,
    policy_sweep_params,
    run_flows,
    run_flows_sized,
    sender_params,
    shard_run_flows,
    shard_sweep_flows_scenarios,
    sweep_flows_scenarios,
)
from repro.net.topology import FatTreeGrid, fat_tree, leaf_spine, null_schedule
from repro.net.transport import Policy

RATE = 16
SPEC = SenderSpec(rate_cap=RATE, early_exit=True)

needs_2dev = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 host devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)",
)


def grid():
    return FatTreeGrid(
        n_pods=3, leaves_per_pod=2, spines_per_pod=2, cores_per_spine=2
    )


PHYS_TIERS = (
    "leaf_spine_up", "spine_core_up", "core_spine_down", "spine_leaf_down"
)


# --------------------------------------------------------------------------
# fat-tree structure
# --------------------------------------------------------------------------

def test_tier_slices_partition_link_axis():
    g = grid()
    sl = g.tier_slices()
    ids = np.concatenate([np.arange(s.start, s.stop) for s in sl.values()])
    assert sorted(ids.tolist()) == list(range(g.links))
    assert sl["bypass"] == slice(g.links - 1, g.links)
    assert g.bypass == g.links - 1
    assert g.n_paths == g.spines_per_pod * g.cores_per_spine


def test_route_hops_land_in_their_tiers():
    g = grid()
    pairs = [(0, 2), (1, 5), (2, 0), (0, 1)]  # 3 inter-pod + 1 intra-pod
    topo = fat_tree(3, 2, 2, 2, pairs)
    route = np.asarray(topo.route)
    assert route.shape == (4, len(pairs), g.n_paths)
    sl = g.tier_slices()

    def in_tier(x, name):
        return ((x >= sl[name].start) & (x < sl[name].stop)).all()

    assert in_tier(route[0], "leaf_spine_up")
    assert in_tier(route[3], "spine_leaf_down")
    inter = np.array([g.pod_of(s) != g.pod_of(d) for s, d in pairs])
    assert in_tier(route[1][inter], "spine_core_up")
    assert in_tier(route[2][inter], "core_spine_down")
    # intra-pod hops 1-2 ride the infinite-capacity bypass link
    assert (route[1][~inter] == g.bypass).all()
    assert (route[2][~inter] == g.bypass).all()
    assert float(np.asarray(topo.capacity)[g.bypass]) >= 1e8
    assert float(np.asarray(topo.degrade_p)[g.bypass]) == 0.0
    # plane discipline: path q = s*C + j enters the fabric through spine s
    # (core plane s connects spine s of EVERY pod)
    q = np.arange(g.n_paths)
    for f in np.flatnonzero(inter):
        sp_up = (route[0, f] - sl["leaf_spine_up"].start) % g.spines_per_pod
        assert (sp_up == q // g.cores_per_spine).all()


def test_fat_tree_validation():
    with pytest.raises(ValueError):
        fat_tree(3, 2, 2, 2, [(0, 0)])           # src == dst
    with pytest.raises(ValueError):
        fat_tree(3, 2, 2, 2, [(0, 6)])           # leaf out of range
    with pytest.raises(ValueError):
        fat_tree(1, 2, 2, 2, [(0, 1)])           # single pod: no core tier


def test_conservation_across_tiers_inter_pod():
    """Ample capacity, no faults: every delivered packet is served once on
    each of the four physical tiers, and the bypass stays silent."""
    g = grid()
    pairs = [(0, 2), (2, 4), (4, 0), (1, 3)]     # all inter-pod
    topo = fat_tree(
        3, 2, 2, 2, pairs, uplink_capacity=64.0, queue_limit=4096.0,
        ecn_threshold=2048.0,
    )
    sp = sender_params(Policy.WAM, rate=RATE)
    r = run_flows(
        topo, null_schedule(topo.links), SPEC, sp, 40,
        jax.random.PRNGKey(0), horizon=512,
    )
    assert bool(np.asarray(r.finished).all())
    served = np.asarray(r.link_served)
    sl = g.tier_slices()
    tier_sums = [float(served[sl[t]].sum()) for t in PHYS_TIERS]
    np.testing.assert_allclose(tier_sums, tier_sums[0], rtol=1e-5)
    assert float(served[sl["bypass"]].sum()) == 0.0
    assert tier_sums[0] > 0


def test_intra_pod_traffic_never_touches_core():
    g = grid()
    pairs = [(0, 1), (2, 3), (4, 5)]             # all intra-pod
    topo = fat_tree(3, 2, 2, 2, pairs, uplink_capacity=64.0)
    sp = sender_params(Policy.WAM, rate=RATE)
    r = run_flows(
        topo, null_schedule(topo.links), SPEC, sp, 40,
        jax.random.PRNGKey(1), horizon=512,
    )
    assert bool(np.asarray(r.finished).all())
    served = np.asarray(r.link_served)
    sl = g.tier_slices()
    assert float(served[sl["spine_core_up"]].sum()) == 0.0
    assert float(served[sl["core_spine_down"]].sum()) == 0.0
    assert float(served[sl["bypass"]].sum()) > 0


def test_fat_tree_scenarios_registry_and_stacking():
    scens = fat_tree_scenarios(flows=8, n_pods=2, horizon=256)
    assert tuple(scens) == FAT_TREE_SCENARIO_NAMES
    topos, scheds = stack_scenarios(list(scens.values()))
    assert topos.route.shape[0] == len(FAT_TREE_SCENARIO_NAMES)
    with pytest.raises(ValueError):
        fat_tree_scenarios(flows=8, n_pods=1)


# --------------------------------------------------------------------------
# pod-aligned placement
# --------------------------------------------------------------------------

def _tiny_job(arch="xlstm-350m", workers=4):
    return compile_job(
        arch, workers=workers, tp=8, iterations=1, rate=RATE,
        min_shard=16, max_shard=48,
        overlap={"allreduce": 0.0, "allgather": 0.0},
    )


def test_place_jobs_pods_alignment():
    jobs = [_tiny_job(workers=3), _tiny_job(workers=4)]
    cl = place_jobs_pods(jobs, leaves_per_pod=2)
    # each job's leaf block starts at a pod boundary
    for cj in cl.jobs:
        assert cj.leaves[0] % 2 == 0
    # leaf blocks are disjoint and the grid rounds up to whole pods
    all_leaves = [lf for cj in cl.jobs for lf in cj.leaves]
    assert len(set(all_leaves)) == len(all_leaves)
    assert cl.n_leaves % 2 == 0
    packed = place_jobs_pods(jobs, leaves_per_pod=2, pack=True)
    assert packed.n_leaves == 4  # max(workers) rounded up to whole pods


def test_cluster_fat_tree_topology_shapes():
    jobs = [_tiny_job(), _tiny_job()]
    cl = place_jobs_pods(jobs, leaves_per_pod=2)
    topo = cluster_fat_tree_topology(cl, leaves_per_pod=2)
    assert topo.flows == cl.flows
    assert topo.hops == 4
    # inter-pod rings exist, so the core tier must be reachable
    g = FatTreeGrid(
        n_pods=cl.n_leaves // 2, leaves_per_pod=2,
        spines_per_pod=2, cores_per_spine=2,
    )
    assert topo.links == g.links


# --------------------------------------------------------------------------
# flow-sharded engine: bit-identity
# --------------------------------------------------------------------------

def _pair_family(flows=4, horizon=256):
    scens = pair_scenarios(flows, 2, horizon=horizon)
    names = list(scens)[:2]
    return stack_scenarios([scens[nm] for nm in names])


def _assert_simresult_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_shard_run_flows_one_device_mesh_bitident():
    topo = leaf_spine(4, 2, [(0, 2), (1, 3), (2, 1), (0, 3)])
    sched = null_schedule(topo.links)
    sp = sender_params(Policy.WAM, rate=RATE)
    key = jax.random.PRNGKey(3)
    ref = run_flows(topo, sched, SPEC, sp, 48, key, horizon=512)
    got = shard_run_flows(
        topo, sched, SPEC, sp, 48, key, 512, mesh=flow_mesh(1)
    )
    _assert_simresult_equal(ref, got)


@pytest.mark.parametrize("n_shards", [2, 3])
def test_vmap_emulated_shards_bitident_padded(n_shards):
    """Non-divisible flow count + per-flow sizes: the padded sharded body
    (collectives emulated by vmap) reproduces `run_flows_sized` exactly."""
    from repro.net.sender import _local_flow_run, _pad_flow_axis, _pad_topology

    topo = leaf_spine(4, 2, [(0, 2), (1, 3), (2, 1), (0, 3), (3, 0)])
    sched = null_schedule(topo.links)
    sp = sender_params(Policy.WAM, rate=RATE)
    key = jax.random.PRNGKey(4)
    F = 5
    sizes = jnp.asarray([48, 0, 24, 64, 16], jnp.int32)
    horizon = 512
    ref = run_flows_sized(topo, sched, SPEC, sp, sizes, key, horizon)

    F_pad = -(-F // n_shards) * n_shards
    topo_g = _pad_topology(topo, F_pad)
    npk_g = _pad_flow_axis(sizes, F_pad, 0, fill=0)
    local = _local_flow_run(SPEC, horizon, F, n_shards)
    run = jax.vmap(
        local, in_axes=(None,) * 5, out_axes=0,
        axis_name=FLOW_AXIS, axis_size=n_shards,
    )
    r = run(topo_g, sched, sp, npk_g, key)

    def stitch(name, x):
        x = np.asarray(x)
        if name in ("link_served", "link_busy"):
            # replicated across shards
            for s in range(1, n_shards):
                np.testing.assert_array_equal(x[0], x[s])
            return x[0]
        return x.reshape((F_pad,) + x.shape[2:])[:F]

    for field in dataclasses.fields(ref):
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, field.name)),
            stitch(field.name, getattr(r, field.name)),
            err_msg=field.name,
        )


@needs_2dev
def test_shard_sweep_flows_scenarios_2dev_bitident():
    topos, scheds = _pair_family()
    sp = policy_sweep_params((Policy.ECMP, Policy.WAM), rate=RATE)
    keys = jax.random.split(jax.random.PRNGKey(5), 2)
    ref = sweep_flows_scenarios(topos, scheds, SPEC, sp, 32, keys, 256)
    got = shard_sweep_flows_scenarios(
        topos, scheds, SPEC, sp, 32, keys, 256, mesh=flow_mesh(2)
    )
    _assert_simresult_equal(ref, got)


@needs_2dev
def test_shard_fat_tree_family_2dev_bitident():
    """The headline path at test scale: the 3-tier family through the
    sharded engine on 2 devices, flow count NOT divisible by the mesh."""
    scens = fat_tree_scenarios(flows=7, n_pods=2, horizon=512)
    topos, scheds = stack_scenarios(list(scens.values()))
    sp = policy_sweep_params((Policy.ECMP, Policy.WAM), rate=RATE)
    keys = jax.random.split(jax.random.PRNGKey(6), 1)
    ref = sweep_flows_scenarios(topos, scheds, SPEC, sp, 16, keys, 512)
    got = shard_sweep_flows_scenarios(
        topos, scheds, SPEC, sp, 16, keys, 512, mesh=flow_mesh(2)
    )
    _assert_simresult_equal(ref, got)


@needs_2dev
def test_sweep_job_mesh_bitident():
    job = _tiny_job()
    scens = job_scenarios(workers=4, horizon=512)
    topo, sched = scens["link_flap"]
    sp = policy_sweep_params((Policy.ECMP, Policy.WAM), rate=RATE)
    keys = jax.random.split(jax.random.PRNGKey(7), 1)
    ref = sweep_job(topo, sched, SPEC, sp, [job], keys, horizon=512)
    got = sweep_job(
        topo, sched, SPEC, sp, [job], keys, horizon=512, mesh=flow_mesh(2)
    )
    for k in ("cct", "finished", "ettr", "exposed"):
        np.testing.assert_array_equal(ref[k], got[k], err_msg=k)


@needs_2dev
def test_sweep_cluster_mesh_bitident_on_fat_tree():
    jobs = [_tiny_job(), _tiny_job()]
    cl = place_jobs_pods(jobs, leaves_per_pod=2)
    topo = cluster_fat_tree_topology(cl, leaves_per_pod=2)
    # static environment: the scenario library's schedules are sized to the
    # leaf-spine link axis, not the fat-tree's
    sched = null_schedule(topo.links)
    sp = policy_sweep_params((Policy.ECMP, Policy.WAM), rate=RATE)
    keys = jax.random.split(jax.random.PRNGKey(8), 1)
    ref = sweep_cluster(topo, sched, SPEC, sp, cl, keys, 1024)
    got = sweep_cluster(
        topo, sched, SPEC, sp, cl, keys, 1024, mesh=flow_mesh(2)
    )
    for k in ("ettr", "solo_ettr", "slowdown", "jain", "link_util"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, k)), np.asarray(getattr(got, k)),
            err_msg=k,
        )
