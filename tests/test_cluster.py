"""Cluster layer: placement, round alignment, emergent contention, metrics.

Covers the placement contract (disjoint vs co-located leaves, per-job
rings, heterogeneous worker counts), the round table (size conservation,
stagger shifting, silence outside a job's window), the per-flow-size sender
path (a uniform size vector is bit-identical to the scalar path; a zeroed
flow completes at tick 0), and the headline physics: on disjoint leaves the
paired solo runs reproduce the contended runs EXACTLY (slowdown == 1 — the
placement shares no link), while overlapped rings slow both jobs down —
contention that emerges from the other job's actual collectives.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.net.cluster import (
    cluster_inputs,
    cluster_round_table,
    cluster_topology,
    jain_index,
    place_jobs,
    run_cluster,
    run_cluster_rounds,
    solo_size_variants,
    sweep_cluster,
)
from repro.net.jobs import compile_job, total_packets
from repro.net.scenarios import CLUSTER_SCENARIO_NAMES, cluster_scenarios
from repro.net.sender import (
    SenderSpec,
    policy_sweep_params,
    run_flows,
    run_flows_sized,
    sender_params,
)
from repro.net.topology import leaf_spine, null_schedule
from repro.net.transport import Policy

WORKERS = 4
RATE = 32
SPEC = SenderSpec(rate_cap=RATE)


def tiny_job(arch, workers=WORKERS, iterations=1, **kw):
    # zero overlap: every tick of communication is exposed, so contention
    # moves ETTR instead of hiding under the compute window
    kw.setdefault("overlap", {"allreduce": 0.0, "allgather": 0.0})
    return compile_job(
        arch, workers=workers, tp=8, iterations=iterations,
        rate=RATE, min_shard=16, max_shard=48, **kw
    )


@pytest.fixture(scope="module")
def jobs():
    return [tiny_job("xlstm-350m"), tiny_job("qwen3-8b")]


def test_placement_disjoint_vs_colocated(jobs):
    disjoint = place_jobs(jobs, colocated=False)
    coloc = place_jobs(jobs, colocated=True)
    assert disjoint.n_leaves == 2 * WORKERS and coloc.n_leaves == WORKERS
    assert disjoint.flows == coloc.flows == 2 * WORKERS
    # disjoint: leaf sets don't intersect; colocated: identical
    a, b = (set(cj.leaves) for cj in disjoint.jobs)
    assert not (a & b)
    a, b = (set(cj.leaves) for cj in coloc.jobs)
    assert a == b
    # each job rides its own ring
    pairs = coloc.flow_pairs()
    fj = coloc.flow_job
    assert pairs.shape == (2 * WORKERS, 2)
    assert np.array_equal(fj, np.repeat([0, 1], WORKERS))
    for j in range(2):
        mine = pairs[fj == j]
        assert np.array_equal(
            mine, [(w, (w + 1) % WORKERS) for w in range(WORKERS)]
        )


def test_placement_heterogeneous_workers():
    jobs = [tiny_job("xlstm-350m", workers=4), tiny_job("qwen3-8b", workers=2)]
    coloc = place_jobs(jobs, colocated=True)
    assert coloc.flows == 6 and coloc.n_leaves == 4
    topo = cluster_topology(coloc, n_spines=4)
    assert topo.flows == 6
    sizes, offsets = cluster_round_table(coloc)
    # R follows the longer schedule; the short job is silent past its end
    assert sizes.shape == (coloc.rounds, 6)
    short = coloc.jobs[1].job
    assert np.all(sizes[short.total_steps:, coloc.job_flows(1)] == 0)


def test_placement_validation(jobs):
    with pytest.raises(ValueError, match="start_steps\\[0\\]"):
        place_jobs(jobs, start_steps=[1, 0])
    with pytest.raises(ValueError, match="start_steps"):
        place_jobs(jobs, start_steps=[0])
    with pytest.raises(ValueError, match="ring"):
        place_jobs([jobs[0], compile_job("qwen3-8b", workers=1, tp=8)])


def test_round_table_conservation_and_stagger(jobs):
    coloc = place_jobs(jobs, colocated=True)
    sizes, offsets = cluster_round_table(coloc)
    R, F = sizes.shape
    assert R == coloc.rounds and F == coloc.flows
    # every packet of every job's schedule lands in exactly one round
    assert int(sizes.sum()) == sum(total_packets(cj.job) for cj in coloc.jobs)
    # and each flow carries exactly its job's per-worker payload
    for j, cj in enumerate(coloc.jobs):
        per_worker = total_packets(cj.job) // cj.job.workers
        assert np.all(sizes[:, coloc.job_flows(j)].sum(axis=0) == per_worker)
    # offsets strictly advance on the global timeline
    assert np.all(np.diff(offsets) > 0)

    stag = place_jobs(jobs, colocated=True, start_steps=[0, 3])
    s_sizes, s_offsets = cluster_round_table(stag)
    assert s_sizes.shape[0] == coloc.rounds + 3
    # job 1's rows are shifted down by 3, job 0's unchanged
    f0, f1 = stag.job_flows(0), stag.job_flows(1)
    assert np.array_equal(s_sizes[:R, f0], sizes[:, f0])
    assert np.all(s_sizes[:3, f1] == 0)
    assert np.array_equal(s_sizes[3:, f1], sizes[:, f1])
    # conservation is stagger-invariant
    assert int(s_sizes.sum()) == int(sizes.sum())


def test_solo_variants_silence_other_jobs(jobs):
    coloc = place_jobs(jobs, colocated=True)
    sizes, _ = cluster_round_table(coloc)
    v = solo_size_variants(coloc, sizes)
    assert v.shape == (3,) + sizes.shape
    assert np.array_equal(v[0], sizes)
    fj = coloc.flow_job
    for j in range(2):
        assert np.array_equal(v[1 + j][:, fj == j], sizes[:, fj == j])
        assert np.all(v[1 + j][:, fj != j] == 0)


def test_per_flow_sizes_match_scalar_path():
    """A uniform per-flow size vector is bit-identical to the scalar traced
    path, and a zeroed flow completes at tick 0 without emitting."""
    topo = leaf_spine(
        WORKERS, 4, [(w, (w + 1) % WORKERS) for w in range(WORKERS)]
    )
    sched = null_schedule(topo.links)
    sp = sender_params(Policy.WAM, rate=RATE)
    key = jax.random.PRNGKey(3)
    r_scalar = run_flows_sized(topo, sched, SPEC, sp, jnp.int32(48), key, 256)
    r_vec = run_flows_sized(
        topo, sched, SPEC, sp, jnp.full((WORKERS,), 48, jnp.int32), key, 256
    )
    for field in ("cct", "sent_total", "dropped_total", "received", "finished"):
        assert np.array_equal(
            np.asarray(getattr(r_scalar, field)),
            np.asarray(getattr(r_vec, field)),
        ), field

    sizes = jnp.asarray([48, 0, 48, 48], jnp.int32)
    r_hole = run_flows_sized(topo, sched, SPEC, sp, sizes, key, 256)
    assert float(r_hole.cct[1]) == 0.0
    assert bool(r_hole.finished[1])
    assert float(r_hole.sent_total[1].sum()) == 0.0
    assert np.all(np.asarray(r_hole.cct)[[0, 2, 3]] > 0)


def test_link_accounting_in_simresult():
    """SimResult now surfaces the shared fabric's conservation counters."""
    topo = leaf_spine(2, 4, [(0, 1)])
    sched = null_schedule(topo.links)
    r = run_flows(
        topo, sched, SPEC, sender_params(Policy.WAM, rate=RATE), 64,
        jax.random.PRNGKey(0), 256,
    )
    assert r.link_served.shape == (topo.links,)
    assert r.link_busy.shape == (topo.links,)
    # serving happened, and busy ticks never exceed capacity-normalized work
    assert float(r.link_served.sum()) > 0
    served, busy = np.asarray(r.link_served), np.asarray(r.link_busy)
    cap = np.asarray(topo.capacity)
    assert np.all(served <= cap * busy + 1e-4)


def test_uncontended_solo_identity_and_overlap_slows(jobs):
    """THE emergence check: disjoint placements share no link, so the
    paired solo variants reproduce the contended run exactly (slowdown 1);
    co-located rings contend and both jobs slow down."""
    scens = cluster_scenarios(jobs, horizon=512)
    key = jax.random.PRNGKey(0)
    sp = sender_params(Policy.WAM, rate=RATE)

    cluster, topo, sched = scens["uncontended"]
    r = run_cluster(topo, sched, SPEC, sp, cluster, key, horizon=384)
    assert bool(r.finished)
    assert np.allclose(r.slowdown, 1.0)
    assert np.allclose(r.jain, 1.0)
    assert np.all((r.ettr > 0) & (r.ettr <= 1))

    cluster, topo, sched = scens["rings_overlapped"]
    r2 = run_cluster(topo, sched, SPEC, sp, cluster, key, horizon=384)
    assert bool(r2.finished)
    # both jobs pay for co-location, and nobody gets a free ride
    assert np.all(r2.slowdown > 1.02)
    assert np.all(r2.ettr <= r2.solo_ettr + 1e-9)
    # utilization is a true fraction of line rate
    assert np.all((r2.link_util >= 0) & (r2.link_util <= 1 + 1e-6))


def test_cluster_scenarios_registry(jobs):
    scens = cluster_scenarios(jobs, horizon=256)
    assert tuple(scens) == CLUSTER_SCENARIO_NAMES
    for name, (cluster, topo, sched) in scens.items():
        assert topo.flows == cluster.flows, name
        assert sched.cap_scale.shape[-1] == topo.links, name
    # staggered placement really staggers
    stag = scens["staggered_start"][0]
    assert stag.jobs[0].start_step == 0 and stag.jobs[1].start_step > 0
    # oversubscribed really has less uplink capacity
    assert float(scens["oversubscribed"][1].capacity[0]) < float(
        scens["rings_overlapped"][1].capacity[0]
    )


def test_sweep_cluster_matches_scalar_runs(jobs):
    """The one-compile policy sweep reproduces per-policy scalar runs."""
    scens = cluster_scenarios(jobs, horizon=512)
    cluster, topo, sched = scens["rings_overlapped"]
    policies = (Policy.ECMP, Policy.WAM)
    sp = policy_sweep_params(policies, rate=RATE)
    keys = jax.random.split(jax.random.PRNGKey(7), 2)
    r = sweep_cluster(topo, sched, SPEC, sp, cluster, keys, horizon=384)
    assert r.ettr.shape == (2, 2, 2)       # [P, D, J]
    assert r.jain.shape == (2, 2)
    assert r.link_util.shape == (2, 2, topo.links)

    from repro.net.cluster import cluster_metrics

    scheds, sizes = cluster_inputs(cluster, sched, 384)
    for pi, pol in enumerate(policies):
        for di in range(2):
            raw = run_cluster_rounds(
                topo, scheds, SPEC, sender_params(pol, rate=RATE), sizes,
                keys[di], 384,
            )
            want = cluster_metrics(cluster, topo, raw)
            assert np.allclose(r.ettr[pi, di], want.ettr), (pol, di)
            assert np.allclose(r.slowdown[pi, di], want.slowdown), (pol, di)
            assert np.allclose(r.jain[pi, di], want.jain), (pol, di)


def test_jain_index():
    assert jain_index(np.ones(4)) == pytest.approx(1.0)
    skew = jain_index(np.asarray([1.0, 0.0, 0.0, 0.0]))
    assert skew == pytest.approx(0.25)
    assert jain_index(np.asarray([1.0, 1.0, 0.5, 0.5])) < 1.0


def test_run_cluster_validates_topology(jobs):
    coloc = place_jobs(jobs, colocated=True)
    wrong = leaf_spine(2, 4, [(0, 1)])
    with pytest.raises(ValueError, match="flows"):
        run_cluster(
            wrong, null_schedule(wrong.links), SPEC,
            sender_params(Policy.WAM, rate=RATE), coloc,
            jax.random.PRNGKey(0), 128,
        )
