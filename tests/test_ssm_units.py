"""SSM/recurrent block units: chunked_scan identity, decode==train step."""
import pytest

pytest.importorskip(
    "repro.dist", reason="seed ships without the repro.dist sharding package"
)
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models import ssm

CFG_JAMBA = get_smoke_config("jamba-v0.1-52b")
CFG_XLSTM = get_smoke_config("xlstm-350m")
KEY = jax.random.PRNGKey(0)


def test_chunked_scan_matches_plain_scan():
    def step(c, x):
        c2 = 0.9 * c + x
        return c2, c2 * 2.0

    xs = jnp.asarray(np.random.default_rng(0).standard_normal((64, 3)), jnp.float32)
    c0 = jnp.zeros((3,))
    want_c, want_y = jax.lax.scan(step, c0, xs)
    got_c, got_y = ssm.chunked_scan(step, c0, xs, chunk=16)
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y), rtol=1e-6)


def test_chunked_scan_grads_match():
    def step(c, x):
        c2 = jnp.tanh(0.9 * c + x)
        return c2, c2

    xs = jnp.asarray(np.random.default_rng(1).standard_normal((32, 4)), jnp.float32)

    def loss_plain(xs_):
        _, ys = jax.lax.scan(step, jnp.zeros((4,)), xs_)
        return jnp.sum(ys ** 2)

    def loss_chunked(xs_):
        _, ys = ssm.chunked_scan(step, jnp.zeros((4,)), xs_, chunk=8)
        return jnp.sum(ys ** 2)

    g1 = jax.grad(loss_plain)(xs)
    g2 = jax.grad(loss_chunked)(xs)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5)


def _seq_equals_decode(init_p, full_fn, prefill_fn, decode_fn, init_state_fn, cfg):
    B, S, D = 2, 32, cfg.d_model
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((B, S, D)) * 0.1, jnp.bfloat16)
    p = init_p(KEY, cfg)
    y_full = full_fn(p, cfg, x)
    # prefix + one decode step
    y_pre, state = prefill_fn(p, cfg, x[:, : S - 1])
    y_dec, _ = decode_fn(p, cfg, x[:, S - 1 :], state)
    np.testing.assert_allclose(
        np.asarray(y_dec[:, 0], np.float32),
        np.asarray(y_full[:, -1], np.float32),
        atol=3e-2, rtol=3e-2,
    )


def test_mamba_decode_matches_train():
    _seq_equals_decode(
        ssm.init_mamba, ssm.mamba, ssm.mamba_prefill, ssm.mamba_decode,
        ssm.mamba_init_state, CFG_JAMBA,
    )


def test_mlstm_decode_matches_train():
    _seq_equals_decode(
        ssm.init_mlstm, ssm.mlstm, ssm.mlstm_prefill, ssm.mlstm_decode,
        ssm.mlstm_init_state, CFG_XLSTM,
    )


def test_slstm_decode_matches_train():
    _seq_equals_decode(
        ssm.init_slstm, ssm.slstm, ssm.slstm_prefill, ssm.slstm_decode,
        ssm.slstm_init_state, CFG_XLSTM,
    )
