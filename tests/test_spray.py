"""Packet spraying (paper §4): selection rule, seeds, memorylessness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core.profile import make_profile
from repro.core.spray import (
    SprayMethod,
    make_spray_state,
    reseed,
    select_path,
    spray_batch,
    spray_key,
    spray_paths,
)

PROFILE = make_profile([127, 400, 200, 173, 124], 10)


@given(st.integers(0, 1023))
def test_select_path_is_paper_rule(k):
    # smallest i with c(i-1) <= k < c(i)
    c = np.asarray(PROFILE.c)
    want = int(np.searchsorted(c, k, side="right"))
    assert int(select_path(PROFILE.c, k)) == want


def test_full_period_counts_exact():
    """Over one full period every ball is selected exactly once, so path
    counts equal b(i) EXACTLY — the deterministic guarantee."""
    for method in (SprayMethod.PLAIN, SprayMethod.SHUFFLE_1, SprayMethod.SHUFFLE_2):
        st_ = make_spray_state(PROFILE, method=method, sa=333, sb=735)
        paths = spray_paths(st_, PROFILE, PROFILE.m)
        counts = np.bincount(np.asarray(paths), minlength=PROFILE.n)
        assert np.array_equal(counts, np.asarray(PROFILE.b)), method


def test_memoryless():
    """Path for counter j depends only on (j, seed, profile)."""
    st0 = make_spray_state(PROFILE, sa=333, sb=735, j0=0)
    st100 = make_spray_state(PROFILE, sa=333, sb=735, j0=100)
    a = np.asarray(spray_paths(st0, PROFILE, 200))[100:]
    b = np.asarray(spray_paths(st100, PROFILE, 100))
    assert np.array_equal(a, b)


def test_batch_matches_sequential():
    st_ = make_spray_state(PROFILE, sa=1, sb=3)
    paths_once = np.asarray(spray_paths(st_, PROFILE, 64))
    got = []
    s = st_
    for _ in range(8):
        p, _, s = spray_batch(s, PROFILE, 8)
        got.append(np.asarray(p))
    assert np.array_equal(np.concatenate(got), paths_once)


def test_path_seq_numbers():
    st_ = make_spray_state(PROFILE, sa=333, sb=735)
    paths, seqs, st2 = spray_batch(st_, PROFILE, 512)
    paths, seqs = np.asarray(paths), np.asarray(seqs)
    for i in range(PROFILE.n):
        mine = seqs[paths == i]
        assert np.array_equal(mine, np.arange(len(mine))), i
    assert np.array_equal(
        np.asarray(st2.path_seq), np.bincount(paths, minlength=PROFILE.n)
    )


def test_empty_bins_never_selected():
    prof = make_profile([0, 512, 0, 512, 0], 10)
    st_ = make_spray_state(prof, sa=5, sb=9)
    paths = np.asarray(spray_paths(st_, prof, prof.m))
    assert set(paths.tolist()) == {1, 3}


@given(
    st.integers(0, 1023),
    st.integers(0, 511).map(lambda x: 2 * x + 1),
    st.sampled_from([SprayMethod.SHUFFLE_1, SprayMethod.SHUFFLE_2]),
)
def test_seeded_keys_are_permutations(sa, sb, method):
    js = np.arange(1024, dtype=np.uint32)
    keys = np.asarray(spray_key(js, np.uint32(sa), np.uint32(sb), 10, method))
    assert sorted(keys.tolist()) == list(range(1024))


@given(
    st.integers(0, 1023),
    st.integers(0, 511).map(lambda x: 2 * x + 1),
    st.sampled_from(list(SprayMethod)),
    st.integers(0, 2**32 - 1),
)
def test_spray_key_batched_matches_scalar(sa, sb, method, j0):
    """The engine sprays whole batches of counters at once (and vmaps them
    across flows); every batched key must equal the scalar paper semantics
    applied per counter."""
    js = (np.uint32(j0) + np.arange(8, dtype=np.uint32)).astype(np.uint32)
    batched = np.asarray(spray_key(js, np.uint32(sa), np.uint32(sb), 10, method))
    scalar = np.array(
        [int(spray_key(j, np.uint32(sa), np.uint32(sb), 10, method)) for j in js]
    )
    assert np.array_equal(batched, scalar)
    vmapped = np.asarray(
        jax.vmap(lambda j: spray_key(j, np.uint32(sa), np.uint32(sb), 10, method))(
            jnp.asarray(js)
        )
    )
    assert np.array_equal(vmapped, scalar)


@given(
    st.lists(st.integers(0, 300), min_size=2, max_size=8),
    st.lists(st.integers(0, 1023), min_size=1, max_size=8),
)
def test_select_path_batched_matches_scalar(bins, keys):
    """Batched / vmapped select_path pins the vmapped engine's path choices
    to the scalar smallest-i-with-c(i-1)<=k<c(i) rule."""
    b = np.asarray(bins, np.int64)
    if b.sum() == 0:
        b[0] = 1
    c = jnp.asarray(np.cumsum(b), jnp.int32)
    keys_a = np.asarray(keys, np.int32) % int(np.sum(b))
    batched = np.asarray(select_path(c, jnp.asarray(keys_a)))
    scalar = np.array([int(select_path(c, int(k))) for k in keys_a])
    assert np.array_equal(batched, scalar)
    vmapped = np.asarray(jax.vmap(lambda k: select_path(c, k))(jnp.asarray(keys_a)))
    assert np.array_equal(vmapped, scalar)


def test_seed_validation():
    with pytest.raises(ValueError):
        make_spray_state(PROFILE, sa=0, sb=2)  # even sb
    with pytest.raises(ValueError):
        make_spray_state(PROFILE, sa=4096, sb=1)  # sa out of range


def test_reseed():
    st_ = make_spray_state(PROFILE, sa=1, sb=3)
    st2 = reseed(st_, 2000, 4)
    assert int(st2.sa) == 2000 % 1024
    assert int(st2.sb) % 2 == 1


def test_jit_compatible():
    st_ = make_spray_state(PROFILE, sa=333, sb=735)
    f = jax.jit(lambda s: spray_batch(s, PROFILE, 128))
    p1, _, _ = f(st_)
    p2 = spray_paths(st_, PROFILE, 128)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
