"""Spray deviation bounds (paper §9, Lemmas 1-7)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.deviation import (
    interval_deviation,
    max_deviation,
    path_deviations,
)
from repro.core.profile import quantize_profile
from repro.core.spray import SprayMethod

ELL = 8  # m=256 keeps the exact O(m^2) deviation computation fast


def test_lemma1_level0_zero():
    # the full-interval deviation is exactly 0 for any seed/method
    for method in (0, 1, 2):
        assert interval_deviation(ELL, method, 33, 77, 0, 1 << ELL) == 0.0


def test_lemma2_interval_deviation_exact():
    """Under shuffle method 1, dev(I) == 1 - 2^-e for level-e intervals."""
    m = 1 << ELL
    for e in (1, 2, 3):
        size = m >> e
        for i in (0, 1, (1 << e) - 1):
            dev = interval_deviation(
                ELL, SprayMethod.SHUFFLE_1, 33, 77, i * size, (i + 1) * size
            )
            assert abs(dev - (1 - 2.0 ** (-e))) < 1e-9, (e, i, dev)


def test_lemma3_interval_bound_method2():
    m = 1 << ELL
    for e in (1, 2, 3):
        size = m >> e
        for i in range(1 << e):
            dev = interval_deviation(
                ELL, SprayMethod.SHUFFLE_2, 33, 77, i * size, (i + 1) * size
            )
            assert dev <= 2 * (1 - 2.0 ** (-e)) + 1e-9, (e, i, dev)


@given(
    st.integers(0, (1 << ELL) - 2),
    st.integers(1, (1 << ELL) - 1),
    st.integers(0, (1 << ELL) - 1),
    st.integers(0, (1 << ELL) // 2 - 1).map(lambda x: 2 * x + 1),
)
@settings(max_examples=20, deadline=None)
def test_lemma6_bound_method1(lo, size, sa, sb):
    hi = min(lo + size, 1 << ELL)
    dev = interval_deviation(ELL, SprayMethod.SHUFFLE_1, sa, sb, lo, hi)
    assert dev <= ELL + 1e-9


@given(
    st.integers(0, (1 << ELL) - 2),
    st.integers(1, (1 << ELL) - 1),
    st.integers(0, (1 << ELL) - 1),
    st.integers(0, (1 << ELL) // 2 - 1).map(lambda x: 2 * x + 1),
)
@settings(max_examples=20, deadline=None)
def test_lemma6_bound_method2(lo, size, sa, sb):
    hi = min(lo + size, 1 << ELL)
    dev = interval_deviation(ELL, SprayMethod.SHUFFLE_2, sa, sb, lo, hi)
    assert dev <= 2 * ELL + 1e-9


@given(
    st.lists(st.floats(0.01, 1.0), min_size=2, max_size=10),
    st.integers(0, 255),
    st.integers(0, 127).map(lambda x: 2 * x + 1),
)
@settings(max_examples=15, deadline=None)
def test_profile_deviation_bound(shares, sa, sb):
    prof = quantize_profile(np.asarray(shares), ELL)
    devs = path_deviations(prof, SprayMethod.SHUFFLE_1, sa, sb)
    assert devs.max() <= ELL + 1e-9


@given(
    st.integers(0, (1 << ELL) - 2),
    st.integers(1, (1 << ELL) - 1),
    st.integers(0, (1 << ELL) - 1),
    st.integers(0, (1 << ELL) // 2 - 1).map(lambda x: 2 * x + 1),
)
@settings(max_examples=15, deadline=None)
def test_combined_method_bound(lo, size, sa, sb):
    """Paper §4: combined two-seed method keeps the §9 bounds (method-2
    form, <= 2*ell)."""
    hi = min(lo + size, 1 << ELL)
    dev = interval_deviation(ELL, SprayMethod.COMBINED, sa, sb, lo, hi)
    assert dev <= 2 * ELL + 1e-9


def test_combined_is_permutation():
    import numpy as np
    from repro.core.spray import spray_key
    keys = np.asarray(spray_key(
        np.arange(1 << ELL, dtype=np.uint32), np.uint32(77), np.uint32(9),
        ELL, SprayMethod.COMBINED,
    ))
    assert sorted(keys.tolist()) == list(range(1 << ELL))


def test_deterministic_beats_random_tail():
    """The quantitative point of the paper: WaM keeps every window within
    O(log m) of target; uniform-random spraying drifts like sqrt(window)."""
    rng = np.random.default_rng(0)
    m = 1 << ELL
    prof = quantize_profile([0.5, 0.5], ELL)
    dev_wam = max_deviation(prof, SprayMethod.SHUFFLE_1, 33, 77)
    # random counterpart: worst window discrepancy over the same horizon
    keys = rng.integers(0, m, 2 * m)
    hits = (keys < m // 2).astype(np.int64)
    pref = np.concatenate([[0], np.cumsum(hits)])
    worst = 0.0
    for j in range(m):
        lens = np.arange(1, m + 1)
        disc = pref[j + lens] - pref[j] - 0.5 * lens
        worst = max(worst, disc.max() - disc.min())
    assert dev_wam <= ELL
    assert worst > dev_wam  # random is strictly worse on this horizon
