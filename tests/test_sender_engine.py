"""Unified sender engine: golden bit-identity, traced-policy dispatch, sweeps.

The acceptance contract of the engine refactor: `simulate_message` on the
independent-bundle seed fabric is BIT-identical to the pre-refactor traces
pinned in tests/golden/transport_seed.npz (regenerate deliberately via
tests/golden/gen_golden_transport.py — never to make a red test green), the
traced-policy `lax.switch` engine matches the per-policy static compiles
element-wise for all five policies on both fabrics and both reliability
modes, and the shared completion threshold guards tiny messages.
"""
import importlib.util
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.net.sender import (
    BASELINE_POLICIES,
    Policy,
    SenderSpec,
    completion_need,
    policy_sweep_params,
    sender_params,
    sweep_flows,
    sweep_message,
)
from repro.net.topology import leaf_spine, null_schedule
from repro.net.transport import TransportConfig, simulate_flows, simulate_message

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
FIELDS = ("cct", "sent_total", "dropped_total", "final_b", "received")


def _load_gen():
    spec = importlib.util.spec_from_file_location(
        "gen_golden_transport",
        os.path.join(GOLDEN_DIR, "gen_golden_transport.py"),
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


GEN = _load_gen()
GOLDEN = np.load(os.path.join(GOLDEN_DIR, "transport_seed.npz"))


def mkparams(n=4):
    return GEN.golden_params(n)


@pytest.mark.parametrize(
    "case", GEN.golden_cases(), ids=lambda c: c[0].replace("/", "-")
)
def test_simulate_message_matches_golden_trace(case):
    name, params, cfg, n_packets, seed, horizon = case
    r = simulate_message(params, cfg, n_packets, jax.random.PRNGKey(seed), horizon)
    for field in FIELDS:
        got = np.asarray(getattr(r, field))
        want = GOLDEN[f"{name}/{field}"]
        assert np.array_equal(got, want), (name, field, got, want)


def test_simulate_flows_matches_golden_trace():
    topo, sched, cfg, n_packets, seed, horizon = GEN.golden_flows_case()
    r = simulate_flows(topo, sched, cfg, n_packets, jax.random.PRNGKey(seed), horizon)
    for field in FIELDS:
        got = np.asarray(getattr(r, field))
        want = GOLDEN[f"FLOWS/WAM/{field}"]
        assert np.array_equal(got, want), field


@pytest.mark.parametrize("coded", [True, False], ids=["coded", "arq"])
def test_traced_policy_matches_static_compiles_bundle_fabric(coded):
    """lax.switch dispatch (one compile, policy a vmap axis) is element-wise
    identical to the per-policy static-cfg compiles on the seed fabric."""
    params = mkparams()
    keys = jax.random.split(jax.random.PRNGKey(5), 2)
    spec = SenderSpec(coded=coded, rate_cap=16)
    sp = policy_sweep_params(rate=16)
    r = sweep_message(params, spec, sp, 128, keys, horizon=256)
    # the default sweep axis is the five baselines; the eight-policy set is
    # covered by tests/test_policy_contract.py with state blocks enabled
    for pi, pol in enumerate(BASELINE_POLICIES):
        cfg = TransportConfig(policy=pol, coded=coded, rate=16)
        for di, k in enumerate(keys):
            ref = simulate_message(params, cfg, 128, k, 256)
            for field in FIELDS:
                got = np.asarray(getattr(r, field))[pi, di]
                want = np.asarray(getattr(ref, field))
                assert np.array_equal(got, want), (pol.name, field)


@pytest.mark.parametrize("coded", [True, False], ids=["coded", "arq"])
def test_traced_policy_matches_static_compiles_shared_fabric(coded):
    topo = leaf_spine(4, 4, [(0, 1), (2, 3)], uplink_capacity=8.0)
    sched = null_schedule(topo.links)
    keys = jax.random.split(jax.random.PRNGKey(9), 2)
    spec = SenderSpec(coded=coded, rate_cap=16)
    sp = policy_sweep_params(rate=16)
    r = sweep_flows(topo, sched, spec, sp, 96, keys, horizon=256)
    for pi, pol in enumerate(BASELINE_POLICIES):
        cfg = TransportConfig(policy=pol, coded=coded, rate=16)
        for di, k in enumerate(keys):
            ref = simulate_flows(topo, sched, cfg, 96, k, 256)
            for field in FIELDS:
                got = np.asarray(getattr(r, field))[pi, di]
                want = np.asarray(getattr(ref, field))
                assert np.array_equal(got, want), (pol.name, field, coded)


def test_completion_need_matches_seed_formula():
    """For non-tiny messages the shared helper reproduces the historical
    threshold exactly: int(K * (1 + eps)) + 1 - 0.25 (coded), K - 0.25 (arq).

    The range deliberately includes every K in [5, 5000): K * (1 + eps)
    landing exactly on an integer (every K divisible by 20 at eps=0.05) is
    where a float32 `1 + eps` formulation flips the floor and silently
    breaks bit-identity with the seed."""
    for n_packets in range(5, 5000):
        want = float(int(n_packets * 1.05) + 1) - 0.25
        got = float(completion_need(n_packets, True, 0.05))
        assert got == np.float32(want), n_packets
    for n_packets in (5, 17, 100, 256, 1024, 4096):
        for eps in (0.0, 0.05, 0.25):
            want = float(int(n_packets * (1.0 + eps)) + 1) - 0.25
            got = float(completion_need(n_packets, True, eps))
            assert got == np.float32(want), (n_packets, eps)
        assert float(completion_need(n_packets, False, 0.05)) == n_packets - 0.25


def test_completion_need_tiny_message_guard():
    # n <= 4: the coded overhead is waived — a 1-packet message needs 1 packet
    for n_packets in (1, 2, 3, 4):
        assert float(completion_need(n_packets, True, 0.05)) == n_packets - 0.25
        assert float(completion_need(n_packets, False, 0.05)) == n_packets - 0.25
    # n == 0: non-positive threshold -> completes at tick 0
    assert float(completion_need(0, True, 0.05)) <= 0.0
    assert float(completion_need(0, False, 0.05)) <= 0.0


@pytest.mark.parametrize("coded", [True, False], ids=["coded", "arq"])
def test_empty_message_completes_at_tick_zero(coded):
    params = mkparams()
    cfg = TransportConfig(policy=Policy.WAM, coded=coded, rate=16)
    r = simulate_message(params, cfg, 0, jax.random.PRNGKey(0), 64)
    assert float(r.cct) == 0.0
    assert float(r.sent_total.sum()) == 0.0

    topo = leaf_spine(2, 4, [(0, 1)], uplink_capacity=8.0)
    rf = simulate_flows(
        topo, null_schedule(topo.links), cfg, 0, jax.random.PRNGKey(0), 64
    )
    assert np.all(np.asarray(rf.cct) == 0.0)


@pytest.mark.parametrize("coded", [True, False], ids=["coded", "arq"])
def test_tiny_messages_complete_without_overhead(coded):
    params = mkparams()
    for n_packets in (1, 2, 4):
        cfg = TransportConfig(policy=Policy.WAM, coded=coded, rate=16)
        r = simulate_message(params, cfg, n_packets, jax.random.PRNGKey(1), 256)
        assert float(r.cct) < 256, (coded, n_packets)  # completed, not sentinel
        assert float(r.received) >= n_packets - 0.25


@pytest.mark.parametrize("coded", [True, False], ids=["coded", "arq"])
def test_finished_mask_tracks_horizon_sentinel(coded):
    """finished is True exactly when cct < horizon; a too-short horizon
    yields the sentinel AND finished == False (no silent flattening)."""
    params = mkparams()
    cfg = TransportConfig(policy=Policy.WAM, coded=coded, rate=16)
    ok = simulate_message(params, cfg, 64, jax.random.PRNGKey(0), 512)
    assert bool(ok.finished) and float(ok.cct) < 512
    short = simulate_message(params, cfg, 4096, jax.random.PRNGKey(0), 8)
    assert not bool(short.finished)
    assert float(short.cct) == 8.0  # the sentinel, flagged as such

    topo = leaf_spine(2, 4, [(0, 1)], uplink_capacity=8.0)
    rf = simulate_flows(
        topo, null_schedule(topo.links), cfg, 4096, jax.random.PRNGKey(0), 8
    )
    assert not np.any(np.asarray(rf.finished))
    assert np.all(np.asarray(rf.cct) == 8.0)


def test_transport_config_seed_validation():
    """Concrete configs keep the historical host-side seed guard (the
    engine's traced seeds are normalized instead — flow-0 semantics)."""
    with pytest.raises(ValueError):
        TransportConfig(policy=Policy.WAM, seed=(333, 734))  # even sb
    with pytest.raises(ValueError):
        TransportConfig(policy=Policy.WAM, seed=(4096, 735))  # sa >= m
    # traced path: an even sb is forced odd, matching run_flows' flow 0
    from repro.net.sender import run_message

    params = mkparams()
    sp_even = sender_params(Policy.WAM, rate=16, seed=(333, 734))
    sp_odd = sender_params(Policy.WAM, rate=16, seed=(333, 735))
    spec = SenderSpec(rate_cap=16)
    key = jax.random.PRNGKey(0)
    r_even = run_message(params, spec, sp_even, 64, key, 256)
    r_odd = run_message(params, spec, sp_odd, 64, key, 256)
    assert np.array_equal(np.asarray(r_even.cct), np.asarray(r_odd.cct))
    assert np.array_equal(
        np.asarray(r_even.sent_total), np.asarray(r_odd.sent_total)
    )


def test_sweep_shapes_and_rate_axis():
    """The sweep axis is any SenderParams field, not just policy: a rate
    sweep shares one program sized by rate_cap."""
    from repro.net.sender import stack_params

    params = mkparams()
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    spec = SenderSpec(rate_cap=16)
    sp = stack_params(
        [sender_params(Policy.WAM, rate=r) for r in (4, 8, 16)]
    )
    r = sweep_message(params, spec, sp, 64, keys, horizon=512)
    assert r.cct.shape == (3, 3)
    ccts = np.asarray(r.cct)
    # higher rate never completes later (healthy-ish fabric, averaged draws)
    assert ccts[0].mean() >= ccts[1].mean() >= ccts[2].mean()
    # rate swept within one program matches the static rate_cap==rate compile
    ref = simulate_message(
        params, TransportConfig(policy=Policy.WAM, rate=16), 64,
        keys[0], 512,
    )
    assert np.array_equal(np.asarray(r.cct)[2, 0], np.asarray(ref.cct))


def test_ring_steps_shared_single_compile_matches_loop():
    """collectives' vmapped ring steps == a Python loop of per-step calls."""
    from repro.net.collectives import ring_steps_cct_shared
    from repro.net.topology import null_schedule as null
    from repro.net import ring_topology

    topo = ring_topology(4, n_spines=4, uplink_capacity=8.0)
    sched = null(topo.links)
    tcfg = TransportConfig(policy=Policy.WAM, rate=16)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    per_step, finished = ring_steps_cct_shared(
        topo, sched, tcfg.spec(), tcfg.params(), 64, keys, 256
    )
    want = [
        float(
            jnp.max(simulate_flows(topo, sched, tcfg, 64, k, 256).cct)
        )
        for k in keys
    ]
    assert np.allclose(np.asarray(per_step), np.asarray(want), atol=0)
    assert bool(np.asarray(finished).all())
