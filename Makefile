# Tier-1 verification + smoke benchmarks.
#   make check   - full tier-1 pytest + benchmark smoke pass
#   make test    - tier-1 pytest only
#   make bench   - full benchmark pass (CSV to stdout)
PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: check test bench bench-smoke

test:
	python -m pytest -x -q

bench-smoke:
	python -m benchmarks.run --smoke --json BENCH_smoke.json

bench:
	python -m benchmarks.run

check: test bench-smoke
