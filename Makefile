# Tier-1 verification + smoke benchmarks + docs checks.
#   make check      - tier-1 pytest + benchmark smoke pass + docs checks
#   make test       - tier-1 pytest only
#   make bench      - full benchmark pass (CSV to stdout)
#   make docs-check - core doctests + markdown relative-link checker
PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: check test bench bench-smoke docs-check

test:
	python -m pytest -x -q

bench-smoke:
	python -m benchmarks.run --smoke --json BENCH_smoke.json

bench:
	python -m benchmarks.run

docs-check:
	python -m pytest --doctest-modules src/repro/core -q
	python tools/check_links.py

check: test bench-smoke docs-check
