# Tier-1 verification + smoke benchmarks + docs checks.
#   make check      - lint + tier-1 pytest + benchmark smoke pass + docs checks
#   make test       - tier-1 pytest only
#   make bench      - full benchmark pass (CSV to stdout)
#   make perf-smoke - gated smoke bench: finished/compile-count gates armed,
#                     telemetry pass on, JSON (with meta.perf + meta.compile
#                     + meta.telemetry) to BENCH_smoke.json, trace artifacts
#                     under traces/ (validated by tools/trace_report.py)
#   make trace-demo - run examples/telemetry_quickstart.py: one flap run,
#                     trace export + report under traces/demo/
#   make docs-check - core doctests + markdown relative-link checker
#   make lint-jax   - repo-specific jax tracer-discipline linter (R1-R5,
#                     tools/jaxlint) over src/repro/{net,core,kernels}
#   make lint       - lint-jax + ruff (curated pyflakes/bugbear set from
#                     pyproject.toml; skipped with a notice if ruff is
#                     not installed — CI installs it via requirements-dev)
PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: check test bench bench-smoke perf-smoke trace-demo docs-check \
	lint lint-jax

test:
	python -m pytest -x -q

lint-jax:
	python -m tools.jaxlint

lint: lint-jax
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks tools; \
	else \
		echo "# ruff not installed -- skipping ruff pass (jaxlint ran)"; \
	fi

bench-smoke:
	python -m benchmarks.run --smoke --json BENCH_smoke.json

# the CI perf gate: every family sweep must stay ONE compiled program
# (--max-compiles bounds the whole run: 8 family programs + 3 telemetry
# programs + 2 scale-out scaling workers + 5 bake-off programs — the four
# 8-policy family sweeps and the recovery pulse — + 2 correlated-failure
# recovery programs (pair + fat-tree, telemetry riding the carry) — with
# headroom) and every gated flow must finish (check_finished fails loudly
# inside the benches; the recovery blackout scenarios strand flows BY
# DESIGN and route through allow_unfinished into meta.degraded instead);
# the bake-off section also writes the BAKEOFF_ranking.json artifact; the
# telemetry pass adds meta.telemetry recovery rows + traces/ artifacts,
# and the exported traces must survive their own reader (trace_report
# exits non-zero on a round-trip or Perfetto-structure failure).
# --devices 2 forces a 2-device host mesh so the scale-out section's
# sharded-vs-unsharded digest gate runs on a real multi-device mesh.
# --audit traces every family's closed jaxpr (no compiles) and fails on
# dtype/effect/telemetry violations or drift from the golden fingerprints
# in tests/golden/program_fingerprints.json (meta.audit + AUDIT_report.json).
perf-smoke:
	python -m benchmarks.run --smoke --devices 2 --json BENCH_smoke.json \
	  --telemetry --trace-dir traces --max-compiles 23 --audit
	python tools/trace_report.py --summary traces/*.jsonl
	python tools/trace_report.py --summary traces/recovery_*.jsonl \
	  --max-recovery-ticks 200
	python tools/trace_report.py --check-perfetto traces/*.trace.json

trace-demo:
	python examples/telemetry_quickstart.py

bench:
	python -m benchmarks.run

docs-check:
	python -m pytest --doctest-modules src/repro/core -q
	python tools/check_links.py

check: lint test perf-smoke docs-check
