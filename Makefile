# Tier-1 verification + smoke benchmarks + docs checks.
#   make check      - tier-1 pytest + benchmark smoke pass + docs checks
#   make test       - tier-1 pytest only
#   make bench      - full benchmark pass (CSV to stdout)
#   make perf-smoke - gated smoke bench: finished/compile-count gates armed,
#                     JSON (with meta.perf + meta.compile) to BENCH_smoke.json
#   make docs-check - core doctests + markdown relative-link checker
PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: check test bench bench-smoke perf-smoke docs-check

test:
	python -m pytest -x -q

bench-smoke:
	python -m benchmarks.run --smoke --json BENCH_smoke.json

# the CI perf gate: every family sweep must stay ONE compiled program
# (--max-compiles bounds the whole run) and every gated flow must finish
# (check_finished fails loudly inside the benches)
perf-smoke:
	python -m benchmarks.run --smoke --json BENCH_smoke.json --max-compiles 10

bench:
	python -m benchmarks.run

docs-check:
	python -m pytest --doctest-modules src/repro/core -q
	python tools/check_links.py

check: test perf-smoke docs-check
